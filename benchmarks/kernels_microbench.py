"""Microbenchmark: Pallas kernels (interpret mode) vs jnp reference, plus
the transport-layer benchmarks (fused OTA uplink, loop-vs-scan trainer).

On CPU this measures the *reference* path's wall time (the kernels execute
interpreted, so wall time is not meaningful for them); the derived numbers
report correctness deltas + the per-element HBM-traffic model that motivates
the fusion (DESIGN.md §6).  The loop-vs-scan trainer numbers ARE meaningful
on CPU: they measure the Python-dispatch + host-sync overhead the scan
driver removes, which is backend-independent.

    PYTHONPATH=src python -m benchmarks.kernels_microbench \
        --out BENCH_transport.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

N = 1 << 20


def _time(fn, iters: int = 10, warmup: int = 3) -> float:
    """Median wall time per call in µs.

    ``warmup`` calls absorb compile + first-touch allocation, then each of
    ``iters`` calls is timed individually with ``time.perf_counter`` and the
    MEDIAN is reported — one GC pause or scheduler hiccup cannot skew the
    number the way a mean over one batched interval does.  Callers must
    ``block_until_ready`` inside ``fn`` (async dispatch would otherwise time
    the enqueue, not the work).
    """
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples) * 1e6)


def microbench():
    k = jax.random.PRNGKey(0)
    args = [jax.random.normal(jax.random.fold_in(k, i), (N,))
            for i in range(5)]

    want = ref.ota_modulate(*args, 0.5)
    got = ops.ota_modulate(*args, 0.5)
    mod_err = float(jnp.max(jnp.abs(got[0] - want[0])))

    ref_j = jax.jit(lambda *a: ref.ota_modulate(*a, 0.5))
    ref_us = _time(lambda: ref_j(*args)[0].block_until_ready())

    # HBM-traffic model (bytes/element): naive = 5 reads + 2 writes per plane
    # with ~3 intermediate materialisations; fused = 5 reads + 2 writes.
    naive_traffic = (5 + 2 + 6) * 4
    fused_traffic = (5 + 2) * 4
    return {
        "n_elements": N,
        "modulate_max_err_vs_ref": mod_err,
        "ref_jit_us_per_call": ref_us,
        "traffic_bytes_per_elem_naive": naive_traffic,
        "traffic_bytes_per_elem_fused": fused_traffic,
        "predicted_fusion_speedup": naive_traffic / fused_traffic,
    }


# ---------------------------------------------------------------------------
# transport layer: fused uplink + loop-vs-scan round driver
# ---------------------------------------------------------------------------

def _uplink_case(W: int, d: int, label: str) -> dict:
    """Fused-OTA round time, jnp vs pallas backend, at one model scale."""
    from repro.core import cplx, transport
    from repro.core.channel import ChannelConfig, rayleigh

    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = cplx.Complex(0.3 * jax.random.normal(k2, (W, d)),
                       0.3 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))
    ccfg = ChannelConfig(n_workers=W, noisy=True)

    def up(backend):
        return jax.jit(lambda t, l, hh, kk: transport.ota_uplink(
            t, l, hh, kk, 0.5, ccfg, backend=backend)[0])

    out = {"label": label, "W": W, "d": d}
    ref_theta = None
    for backend in ("jnp", "pallas"):
        f = up(backend)
        theta_out = f(theta, lam, h, key)
        if ref_theta is None:
            ref_theta = theta_out
        else:
            out["max_abs_err_vs_jnp"] = float(
                jnp.max(jnp.abs(theta_out - ref_theta)))
        out[f"{backend}_us_per_round"] = _time(
            lambda f=f: f(theta, lam, h, key).block_until_ready())
    # the one-pass fused round (ISSUE 6) on the same planes
    fused = jax.jit(lambda t, l, hh, kk: transport.ota_round_fused(
        t, l, hh, kk, 0.5, ccfg, backend="jnp")[0])
    fused(theta, lam, h, key)
    out["fused_us_per_round"] = _time(
        lambda: fused(theta, lam, h, key).block_until_ready())
    out["speedup_fused_over_composed"] = (
        out["jnp_us_per_round"] / out["fused_us_per_round"])
    # elementwise HLO count the fusion collapses (modulate, scale, mul, sum,
    # noise-add, div, eps-max -> one kernel): traffic model as above.
    out["hbm_passes_unfused"] = 5
    out["hbm_passes_fused"] = 1
    return out


def _trainer_case(n_rounds: int, eval_every: int) -> dict:
    """Python-loop vs scan-compiled driver on the paper's linreg task.

    Two numbers per driver:

    * ``*_seconds_end_to_end`` — one cold ``train`` call (includes trace +
      compile: what a one-shot figure run actually pays).
    * ``compiled_dispatch`` — the already-compiled round/chunk functions
      dispatched back-to-back with no Python re-tracing and no host pulls:
      isolates the per-round dispatch overhead the scan driver removes
      (n dispatches vs n/coherence).
    """
    from benchmarks.common import (LINREG_WORKERS, linreg_algorithm,
                                   make_linreg_task)
    from repro.train import train

    key = jax.random.PRNGKey(0)
    task = make_linreg_task(key)
    alg, solver = linreg_algorithm("afadmm", task)
    block = alg.ccfg.coherence_iters

    out = {"n_rounds": n_rounds, "workers": LINREG_WORKERS,
           "coherence_iters": block}
    hist = {}
    for driver in ("loop", "scan"):
        t0 = time.time()
        hist[driver] = train(alg, task.theta0, solver, task.grad_fn,
                             n_rounds, jax.random.PRNGKey(1),
                             eval_fn=task.eval_fn, eval_every=eval_every,
                             driver=driver)
        out[f"{driver}_seconds_end_to_end"] = time.time() - t0
    out["speedup_scan_over_loop_end_to_end"] = \
        out["loop_seconds_end_to_end"] / out["scan_seconds_end_to_end"]

    st = alg.init(jax.random.PRNGKey(1), task.theta0)
    round_j = jax.jit(lambda s, k: alg.round(k, s, solver, task.grad_fn))
    chunk_j = jax.jit(lambda s, rs: alg.scan_rounds(
        jax.random.PRNGKey(1), s, solver, task.grad_fn, rs))
    rs = jnp.arange(block, dtype=jnp.int32)
    jax.block_until_ready(round_j(st, key))           # compile
    jax.block_until_ready(chunk_j(st, rs))

    # both branches execute exactly n_eff rounds so the speedup compares
    # equal work even when the coherence block doesn't divide n_rounds
    n_chunks = n_rounds // block
    n_eff = n_chunks * block
    t0 = time.time()
    s = st
    for r in range(n_eff):
        s, _ = round_j(s, jax.random.fold_in(key, r))
    jax.block_until_ready(s)
    t_loop = time.time() - t0
    t0 = time.time()
    s = st
    for c in range(n_chunks):
        s, _ = chunk_j(s, rs + c * block)
    jax.block_until_ready(s)
    t_scan = time.time() - t0
    out["compiled_dispatch"] = {
        "n_rounds_timed": n_eff,
        "loop_n_dispatches": n_eff, "loop_seconds": t_loop,
        "scan_n_dispatches": n_chunks, "scan_seconds": t_scan,
        "speedup_scan_over_loop": t_loop / t_scan,
    }

    out["history_bitwise_equal"] = bool(
        hist["loop"].loss == hist["scan"].loss
        and hist["loop"].channel_uses == hist["scan"].channel_uses)
    return out


def transport_microbench():
    from benchmarks.common import MLP_WORKERS, make_mlp_task

    d_mlp = int(make_mlp_task(jax.random.PRNGKey(0)).d)
    return {
        "uplink_linreg": _uplink_case(10, 6, "linreg (paper Sec. 5)"),
        "uplink_mlp": _uplink_case(MLP_WORKERS, d_mlp, "MLP (FAST scale)"),
        # eval_every=1 is the figure benchmarks' cadence (one eval host
        # sync per round in the loop driver — the worst case scan removes).
        # One trainer case only: a second one in the same process would
        # have its end-to-end timing skewed by XLA executable-cache hits
        # from the first.
        "trainer_linreg_300r": _trainer_case(300, eval_every=1),
        # wall-clock contract field (bench methodology: every BENCH json's
        # optimised metric is a measured speedup, never a proxy count)
        "optimised_metric": "uplink_mlp.speedup_fused_over_composed",
    }


# ---------------------------------------------------------------------------
# packed vs per-leaf pytree uplink (one fused receive per round)
# ---------------------------------------------------------------------------

def _count_uplink_entries(round_fn, *args) -> int:
    """Trace ``round_fn`` once and count uplink entry points: composed
    ``transport.receive`` chains plus one-pass fused entries
    (``ota_round_fused`` / ``ota_round_stats``).  Each is one receive
    kernel chain in the lowered HLO — the dispatch contract is "one uplink
    entry per round" whichever path is active."""
    from repro.core import transport

    calls = {"n": 0, "depth": 0}
    names = ("receive", "ota_round_fused", "ota_round_stats")
    orig = {n: getattr(transport, n) for n in names}

    def counting(n):
        def f(*a, **kw):
            # ota_round_fused reaches ota_round_stats internally: only the
            # outermost entry is a round-level uplink
            if calls["depth"] == 0:
                calls["n"] += 1
            calls["depth"] += 1
            try:
                return orig[n](*a, **kw)
            finally:
                calls["depth"] -= 1
        return f

    for n in names:
        setattr(transport, n, counting(n))
    try:
        jax.eval_shape(round_fn, *args)
    finally:
        for n in names:
            setattr(transport, n, orig[n])
    return calls["n"]


def _tree_uplink_case(label: str, theta, lam, h, W: int) -> dict:
    """Packed vs per-leaf ota_tree_round on one (multi-leaf) model."""
    from repro.core.admm import AdmmConfig
    from repro.core.channel import ChannelConfig
    from repro.core.tree_ota import ota_tree_round, ota_tree_round_leafwise

    acfg = AdmmConfig(rho=0.5, power_control=True)
    ccfg = ChannelConfig(n_workers=W, noisy=True)
    key = jax.random.PRNGKey(0)
    n_leaves = len(jax.tree_util.tree_leaves(theta))
    d_total = sum(l.size for l in jax.tree_util.tree_leaves(theta)) // W

    out = {"label": label, "W": W, "n_leaves": n_leaves, "d": d_total}
    for name, fn in (("packed", ota_tree_round),
                     ("per_leaf", ota_tree_round_leafwise)):
        round_fn = lambda t, l, hh, k, fn=fn: fn(t, l, hh, k, acfg, ccfg,
                                                 backend="jnp")[0]
        out[f"{name}_uplink_entries_per_round"] = _count_uplink_entries(
            round_fn, theta, lam, h, key)
        j = jax.jit(round_fn)
        jax.block_until_ready(j(theta, lam, h, key))         # compile
        out[f"{name}_us_per_round"] = _time(
            lambda: jax.block_until_ready(j(theta, lam, h, key)), iters=30)
    out["speedup_packed_over_per_leaf"] = (
        out["per_leaf_us_per_round"] / out["packed_us_per_round"])
    # Wall-clock is the optimised metric (bench methodology contract).  The
    # entry count is still recorded — each uplink entry is a receive
    # kernel-chain launch on TPU (hundreds/round on transformer configs
    # before packing) — but the packed round now runs the one-pass fused
    # receive, so the CPU wall-clock comparison is the honest headline.
    # Note this case re-packs λ/h every round; the persistently-packed
    # state path is the fused_round lane.
    out["optimised_metric"] = "speedup_packed_over_per_leaf"
    return out


def _mlp_trees(W: int):
    from repro.core import cplx
    from repro.core.channel import rayleigh

    key = jax.random.PRNGKey(1)
    sizes = (64, 32, 16, 10)
    theta = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        theta[f"w{i}"] = jax.random.normal(
            jax.random.fold_in(key, 2 * i), (W, a, b))
        theta[f"b{i}"] = jax.random.normal(
            jax.random.fold_in(key, 2 * i + 1), (W, b))
    lam = jax.tree.map(lambda l: cplx.czero(l.shape), theta)
    hkey = jax.random.fold_in(key, 1000)
    leaves, treedef = jax.tree_util.tree_flatten(theta)
    h = jax.tree_util.tree_unflatten(treedef, [
        rayleigh(jax.random.fold_in(hkey, i), l.shape)
        for i, l in enumerate(leaves)])
    return theta, lam, h


def _transformer_trees(W: int):
    from repro.core import cplx
    from repro.core.tree_ota import init_channel_tree
    from repro.models.registry import get_model

    model = get_model("granite-8b", reduced=True)
    theta = jax.vmap(model.init)(jax.random.split(jax.random.PRNGKey(2), W))
    lam = jax.tree.map(lambda l: cplx.czero(l.shape, jnp.float32), theta)
    h = init_channel_tree(jax.random.PRNGKey(3), theta).h
    return theta, lam, h


def packed_microbench() -> dict:
    W = 4
    mlp = _tree_uplink_case("MLP 64-32-16-10", *_mlp_trees(W), W)
    tfm = _tree_uplink_case("transformer granite-8b (reduced)",
                            *_transformer_trees(W), W)
    return {"uplink_mlp_tree": mlp, "uplink_transformer_tree": tfm}


# ---------------------------------------------------------------------------
# fused one-pass OTA round (ISSUE 6): wall-clock vs composed + leafwise
# ---------------------------------------------------------------------------

def fused_round_microbench() -> dict:
    """ISSUE 6 exit bar: on the persistently-packed state the ONE-PASS fused
    receive (``transport.ota_round_fused`` — each worker plane read once per
    round) must beat the composed packed chain AND at minimum match the
    leafwise round on wall-clock, while issuing exactly one uplink entry per
    round.  Also times the worker-chunked cohort stream and runs a W=256
    streamed round (peak signal memory O(chunk·D) — pinned structurally in
    ``tests/test_fused_round.py``)."""
    from repro.core import transport
    from repro.core.admm import AdmmConfig
    from repro.core.channel import ChannelConfig, rayleigh
    from repro.core.cplx import Complex
    from repro.core.packing import build_packspec, pack_cplx
    from repro.core.tree_ota import (ota_tree_round_leafwise,
                                     ota_tree_round_packed_state)

    W = 4
    theta, lam, h = _transformer_trees(W)
    spec = build_packspec(theta, batch_dims=1)
    lam_p = pack_cplx(spec, lam)
    h_p = pack_cplx(spec, h)
    acfg = AdmmConfig(rho=0.5, power_control=True, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, noisy=True)
    key = jax.random.PRNGKey(0)

    def packed_round(fused, worker_chunk=None):
        return jax.jit(lambda t, lp, hp, k: ota_tree_round_packed_state(
            t, lp, hp, k, acfg, ccfg, spec, backend="jnp", fused=fused,
            worker_chunk=worker_chunk)[0])

    def leaf_round(t, l, hh, k):
        return ota_tree_round_leafwise(t, l, hh, k, acfg, ccfg,
                                       backend="jnp")[0]

    out = {"W": W, "d": spec.d,
           "n_leaves": len(jax.tree_util.tree_leaves(theta))}
    out["fused_uplink_entries_per_round"] = _count_uplink_entries(
        lambda t, lp, hp, k: ota_tree_round_packed_state(
            t, lp, hp, k, acfg, ccfg, spec, backend="jnp")[0],
        theta, lam_p, h_p, key)

    # the in-repo autotune sweep, at round granularity: worker_chunk is THE
    # lever on CPU (cohort streaming = cache blocking — a (chunk, D) working
    # set instead of (W, D)); the tuned config is what a deployment sets via
    # REPRO_OTA_WORKER_CHUNK / FLConfig.ota_worker_chunk, so the tuned
    # number is the honest fused headline
    T_ref = jax.block_until_ready(packed_round(None)(theta, lam_p, h_p, key))
    sweep = {}
    for wc in (0, 1, 2):
        j = packed_round(None, worker_chunk=wc or None)
        T = jax.block_until_ready(j(theta, lam_p, h_p, key))
        errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(T_ref),
                                jax.tree_util.tree_leaves(T))]
        assert max(errs) <= 1e-4, (wc, max(errs))
        sweep[wc] = _time(
            lambda j=j: jax.block_until_ready(j(theta, lam_p, h_p, key)),
            iters=30)
    best_chunk = min(sweep, key=sweep.get)
    out["fused_chunk_sweep_us"] = {str(k): v for k, v in sweep.items()}
    out["fused_worker_chunk"] = best_chunk
    out["fused_packed_us_per_round"] = sweep[best_chunk]
    out["fused_monolithic_us_per_round"] = sweep[0]

    j_comp = packed_round(False)
    T_comp = jax.block_until_ready(j_comp(theta, lam_p, h_p, key))
    errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree_util.tree_leaves(T_ref),
                            jax.tree_util.tree_leaves(T_comp))]
    out["composed_max_abs_err_vs_fused"] = max(errs)  # bitwise: 0.0
    out["composed_packed_us_per_round"] = _time(
        lambda: jax.block_until_ready(j_comp(theta, lam_p, h_p, key)),
        iters=30)
    j_leaf = jax.jit(leaf_round)
    jax.block_until_ready(j_leaf(theta, lam, h, key))
    out["leafwise_us_per_round"] = _time(
        lambda: jax.block_until_ready(j_leaf(theta, lam, h, key)), iters=30)

    out["speedup_fused_over_composed"] = (
        out["composed_packed_us_per_round"]
        / out["fused_packed_us_per_round"])
    out["speedup_fused_over_leafwise"] = (
        out["leafwise_us_per_round"] / out["fused_packed_us_per_round"])

    # W=256 cohort-streamed smoke on flat planes: the scale the monolithic
    # pass cannot hold at O(W·D) signal memory
    Wb, db, chunk = 256, 1 << 15, 32
    kb = jax.random.fold_in(key, 1)
    tb = jax.random.normal(kb, (Wb, db), jnp.float32)
    lb = Complex(0.3 * jax.random.normal(jax.random.fold_in(kb, 1),
                                         (Wb, db)),
                 0.3 * jax.random.normal(jax.random.fold_in(kb, 2),
                                         (Wb, db)))
    hb = rayleigh(jax.random.fold_in(kb, 3), (Wb, db))
    cb = ChannelConfig(n_workers=Wb, noisy=True)
    js = jax.jit(lambda t, l, hh, k: transport.ota_round_fused(
        t, l, hh, k, 0.5, cb, worker_chunk=chunk, backend="jnp")[0])
    jax.block_until_ready(js(tb, lb, hb, kb))
    out["w256_streamed"] = {
        "W": Wb, "d": db, "worker_chunk": chunk,
        "us_per_round": _time(
            lambda: jax.block_until_ready(js(tb, lb, hb, kb)), iters=5),
        "peak_signal_plane_elems": 4 * chunk * db,
        "monolithic_signal_plane_elems": 4 * Wb * db,
    }
    # wall-clock IS the optimised metric — the exit bar of this PR
    out["optimised_metric"] = "speedup_fused_over_composed"
    return out


# ---------------------------------------------------------------------------
# shard-local packed uplink (model-parallel meshes)
# ---------------------------------------------------------------------------

def shard_local_microbench() -> dict:
    """ISSUE 5 contract numbers: under a model-parallel mesh the shard-local
    round issues exactly ONE ``transport.receive`` per shard per round (the
    ``shard_map`` body traces once and executes on every model shard — no
    leafwise fallback, no per-leaf chains), its noise-free output is
    BITWISE equal to the ``ota_tree_round_leafwise`` oracle, and λ/h stay
    in the shard-local (W, d_pad) layout end to end.

    Needs >= 2 devices — ``main()`` forces
    ``--xla_force_host_platform_device_count=2`` before jax initialises.
    """
    import numpy as np

    from repro.core.admm import AdmmConfig
    from repro.core.channel import ChannelConfig
    from repro.core.packing import (build_shard_packspec,
                                    pack_shard_global_cplx,
                                    unpack_shard_global_cplx)
    from repro.core.tree_ota import (ota_tree_round_leafwise,
                                     ota_tree_round_shard_local)
    from repro.launch.shardings import model_shard_dims
    from repro.models.registry import get_model

    if jax.device_count() < 2:
        raise RuntimeError(
            "shard-local bench needs >= 2 devices "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    W, n_shards = 4, 2
    mesh = jax.make_mesh((1, n_shards), ("data", "model"))
    model = get_model("granite-8b", reduced=True)
    theta, lam, h = _transformer_trees(W)
    dims = model_shard_dims(theta, model.cfg, mesh, multi_pod=False)
    sspec = build_shard_packspec(theta, dims, n_shards, batch_dims=1)
    lam_p = pack_shard_global_cplx(sspec, lam)
    h_p = pack_shard_global_cplx(sspec, h)
    acfg = AdmmConfig(rho=0.5, power_control=True, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False)
    key = jax.random.PRNGKey(0)

    def shard_round(t, lp, hp, k):
        return ota_tree_round_shard_local(t, lp, hp, k, acfg, ccfg, sspec,
                                          mesh, backend="jnp")

    def leaf_round(t, l, hh, k):
        return ota_tree_round_leafwise(t, l, hh, k, acfg, ccfg,
                                       backend="jnp")

    with mesh:
        uplink_entries = _count_uplink_entries(
            lambda t, lp, hp, k: shard_round(t, lp, hp, k)[0],
            theta, lam_p, h_p, key)
        j_shard = jax.jit(shard_round)
        T_s, lam_s, m_s = jax.block_until_ready(
            j_shard(theta, lam_p, h_p, key))
        us_shard = _time(lambda: jax.block_until_ready(
            j_shard(theta, lam_p, h_p, key)), iters=10)
    j_leaf = jax.jit(leaf_round)
    T_l, lam_l, m_l = jax.block_until_ready(j_leaf(theta, lam, h, key))
    us_leaf = _time(lambda: jax.block_until_ready(
        j_leaf(theta, lam, h, key)), iters=10)

    errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree_util.tree_leaves(T_s),
                            jax.tree_util.tree_leaves(T_l))]
    lam_back = unpack_shard_global_cplx(sspec, lam_s)
    lam_errs = []
    for a, b in zip(jax.tree_util.tree_leaves(lam_back),
                    jax.tree_util.tree_leaves(lam_l)):
        lam_errs.append(float(jnp.max(jnp.abs(a - b))))
    n_leaves = len(jax.tree_util.tree_leaves(theta))
    return {
        "n_shards": n_shards, "W": W, "n_leaves": n_leaves,
        "d": sspec.spec.d, "d_local": sspec.d_local, "d_pad": sspec.d_pad,
        # ONE body trace = one fused receive chain per shard per round
        "uplink_entries_per_shard_per_round": uplink_entries,
        "leafwise_receive_dispatches_per_round": n_leaves,
        "noise_free_max_abs_err_vs_leafwise": max(errs),
        "noise_free_lam_max_abs_err_vs_leafwise": max(lam_errs),
        "inv_alpha_equal": bool(float(m_s["inv_alpha"])
                                == float(m_l["inv_alpha"])),
        "shard_local_us_per_round": us_shard,
        "leafwise_us_per_round": us_leaf,
        "speedup_shard_local_over_leafwise": us_leaf / us_shard,
        # Wall-clock is the optimised metric (bench methodology contract) —
        # measured here through shard_map over 2 simulated host devices, so
        # it is a weak proxy; the production evidence is the 16x16 dryrun:
        # 5.6s vs 27s compile and 80 vs 164 per-round collective-permutes
        # (the CI dryrun assert), with the entry count pinned at 1.
        "optimised_metric": "speedup_shard_local_over_leafwise",
    }


# ---------------------------------------------------------------------------
# sketched A-FADMM-CS on the shard-local packed transport
# ---------------------------------------------------------------------------

def sketched_microbench() -> dict:
    """The re-homed sketched path's contract numbers: A-FADMM-CS consensus
    rides the packed OTA transport, so one sketched round issues exactly
    ONE uplink entry (the fused receive) per shard per round — no private
    per-leaf codec chains — while the codec encodes/decodes shard-locally
    on a (data, fsdp, model) mesh and a phy scenario threads its (W,)
    participation mask into the sketched worker scan.

    Needs >= 4 devices — ``main()`` forces
    ``--xla_force_host_platform_device_count=4`` before jax initialises.
    """
    from repro.core.admm import AdmmConfig
    from repro.core.channel import ChannelConfig
    from repro.core.packing import build_packspec
    from repro.models.registry import get_model
    from repro.models.sharding import axis_rules
    from repro.train.llm_trainer import FLConfig, make_fl_train

    if jax.device_count() < 4:
        raise RuntimeError(
            "sketched bench needs >= 4 devices "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    mesh = jax.make_mesh((1, 2, 2), ("data", "fsdp", "model"))
    model = get_model("granite-8b", reduced=True)
    W, B, T = 4, 2, 16
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (W, B, T), 0,
                                          model.cfg.vocab_size)}
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
    flcfg = FLConfig(mode="sketched", n_workers=W, local_steps=1,
                     local_lr=1e-2, sketch_ratio=16, sketch_lr=0.7,
                     scenario="deep-fade-truncation", h_min=0.8)
    init_fn, train_step = make_fl_train(model, flcfg, acfg, ccfg, mesh=mesh)
    # full-dim replicated round on the same mesh: the uplink the sketch
    # compresses away (paper Sec. 6 — consensus in d_s instead of d)
    flcfg_r = FLConfig(mode="replicated", n_workers=W, local_steps=1,
                      local_lr=1e-2)
    init_r, step_r = make_fl_train(model, flcfg_r, acfg, ccfg, mesh=mesh)

    with mesh:
        with axis_rules(mesh):
            st = init_fn(key)
            uplink_entries = _count_uplink_entries(train_step, st, batch,
                                                   key)
            step = jax.jit(train_step)
            st2, met = jax.block_until_ready(step(st, batch, key))
            us_round = _time(lambda: jax.block_until_ready(
                step(st, batch, key)), iters=5)
            st_r = init_r(key)
            jstep_r = jax.jit(step_r)
            jax.block_until_ready(jstep_r(st_r, batch, key))
            us_repl = _time(lambda: jax.block_until_ready(
                jstep_r(st_r, batch, key)), iters=5)

    d = build_packspec(st.Theta).d
    d_s = int(st.lam.re.shape[-1])
    return {
        "W": W, "n_fsdp": 2, "n_model": 2,
        "d": d, "d_s": d_s, "compression_ratio": d / d_s,
        # ONE fused receive per shard per sketched round — the re-home
        # contract (the deleted per-leaf hashed-tree codec issued one
        # scatter-add per leaf instead)
        "uplink_entries_per_shard_per_round": uplink_entries,
        "scenario": flcfg.scenario,
        "participation": float(met["participation"]),
        "loss_finite": bool(jnp.isfinite(met["loss"])),
        "sketched_us_per_round": us_round,
        "replicated_us_per_round": us_repl,
        "speedup_sketched_over_replicated": us_repl / us_round,
        # Wall-clock is the optimised metric: the sketched round's OTA
        # consensus runs in d_s instead of d.  Measured through shard_map
        # over 4 simulated host devices (weak proxy); the production
        # evidence is the qwen1.5-110b sketched dryrun in CI.
        "optimised_metric": "speedup_sketched_over_replicated",
    }


# ---------------------------------------------------------------------------
# fault guards: guarded-vs-unguarded round overhead + chaos smoke
# ---------------------------------------------------------------------------

def faults_microbench() -> dict:
    """ISSUE 7 exit bar: the round health guard on a HEALTHY slot costs
    <= 5% over the unguarded fused round (median-of-k wall clock — the
    guard adds only the O(d) finiteness/SNR epilogue, and its output is
    BITWISE the unguarded round), and a chaos run (25% crashed workers +
    one persistent-NaN worker under ``evict-retransmit``) stays finite
    end to end."""
    import dataclasses

    from repro.core import transport
    from repro.core.channel import ChannelConfig, rayleigh
    from repro.core.cplx import Complex
    from repro.faults import FaultPlan, GuardConfig, guarded_ota_round

    W, d, rho = 8, 1 << 16, 0.5
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = Complex(0.3 * jax.random.normal(k2, (W, d)),
                  0.3 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    gcfg = GuardConfig(policy="evict-retransmit", snr_floor_db=-60.0)

    un_j = jax.jit(lambda t, l, hh, k: transport.ota_round_fused(
        t, l, hh, k, rho, ccfg, backend="jnp")[0])
    g_j = jax.jit(lambda t, l, hh, k: guarded_ota_round(
        t, l, hh, k, rho, ccfg, gcfg, backend="jnp").Theta)
    T0 = jax.block_until_ready(un_j(theta, lam, h, key))
    T1 = jax.block_until_ready(g_j(theta, lam, h, key))
    out = {"W": W, "d": d,
           "healthy_max_abs_err_vs_unguarded": float(
               jnp.max(jnp.abs(T1 - T0)))}  # bitwise contract: 0.0
    out["unguarded_us_per_round"] = _time(
        lambda: un_j(theta, lam, h, key).block_until_ready(), iters=30)
    out["guarded_us_per_round"] = _time(
        lambda: g_j(theta, lam, h, key).block_until_ready(), iters=30)
    out["guard_overhead_x"] = (out["guarded_us_per_round"]
                               / out["unguarded_us_per_round"])

    # chaos smoke on the paper's linreg task: workers 1 and 2 of 8 crash
    # (25%), worker 0 uploads NaN planes every round (evicted), bursts
    # force retransmissions — the guarded run must stay finite
    from benchmarks.common import linreg_algorithm, make_linreg_task
    from repro.train import train

    task = make_linreg_task(key, n_workers=W)
    alg, solver = linreg_algorithm("afadmm", task)
    fp = FaultPlan(crash_at=((3, 1), (6, 2)), nan_workers=1,
                   burst_prob=0.2, burst_std=5.0)
    # the chaos floor must sit ABOVE the burst SNR (~-36 dB at std 5) so
    # burst rounds retransmit instead of being accepted corrupted; the
    # healthy receive SNR is ~40 dB, far above the floor
    chaos_guard = dataclasses.replace(gcfg, snr_floor_db=0.0)
    alg = dataclasses.replace(
        alg, acfg=dataclasses.replace(alg.acfg, flip_on_change=False),
        faults=fp, guard=chaos_guard)
    hist = train(alg, task.theta0, solver, task.grad_fn, 40,
                 jax.random.PRNGKey(1), eval_fn=task.eval_fn,
                 eval_every=10, driver="scan")
    out["chaos"] = {
        "n_rounds": 40, "crashed_workers": 2, "nan_workers": 1,
        "all_evals_finite": bool(np.all(np.isfinite(hist.loss))),
        "final_loss_gap": float(hist.loss[-1]),
        "alive_final": float(hist.extra["fault/alive"][-1]),
        "guard_evictions": float(sum(hist.extra["guard/evicted"])),
        "guard_retries": float(sum(hist.extra["guard/retries"])),
    }
    # wall-clock contract field (bench methodology): the optimised metric
    # here is an OVERHEAD bound, not a speedup — the guard buys fault
    # tolerance and must cost (almost) nothing on the healthy path
    out["optimised_metric"] = "guard_overhead_x"
    return out


# ---------------------------------------------------------------------------
# observability: in-graph telemetry overhead + structured-log smoke
# ---------------------------------------------------------------------------

def obs_microbench() -> dict:
    """ISSUE 9 exit bar: telemetry-on costs <= 5% over the bare fused
    round (the obs/ statistics reuse values the receive already has in
    registers) and does NOT change the training math (Theta bitwise); the
    MetricsSink smoke run emits schema-valid JSONL."""
    import tempfile

    from repro.core import transport
    from repro.core.channel import ChannelConfig, rayleigh
    from repro.core.cplx import Complex
    from repro.obs.sink import MetricsSink, run_manifest
    from repro.obs.validate import validate_run_dir

    W, d, rho = 8, 1 << 16, 0.5
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = Complex(0.3 * jax.random.normal(k2, (W, d)),
                  0.3 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)

    off_j = jax.jit(lambda t, l, hh, k: transport.ota_round_fused(
        t, l, hh, k, rho, ccfg, backend="jnp")[0])
    def _on(t, l, hh, k):
        r = transport.ota_round_fused(t, l, hh, k, rho, ccfg,
                                      backend="jnp", telemetry=True)
        return r[0], r[3]   # (Theta, telemetry metrics)

    on_j = jax.jit(_on)
    T0 = jax.block_until_ready(off_j(theta, lam, h, key))
    T1, telm = on_j(theta, lam, h, key)
    jax.block_until_ready(T1)
    out = {"W": W, "d": d,
           "telemetry_max_abs_err": float(jnp.max(jnp.abs(T1 - T0))),
           "telemetry_keys": sorted(telm)}  # bitwise contract: 0.0
    out["bare_us_per_round"] = _time(
        lambda: off_j(theta, lam, h, key).block_until_ready(), iters=30)
    out["telemetry_us_per_round"] = _time(
        lambda: on_j(theta, lam, h, key)[0].block_until_ready(), iters=30)
    out["telemetry_overhead_x"] = (out["telemetry_us_per_round"]
                                   / out["bare_us_per_round"])

    # structured-log smoke: a short flat-trainer run through a MetricsSink,
    # then the CI schema linter over the result
    from benchmarks.common import linreg_algorithm, make_linreg_task
    from repro.train import train

    task = make_linreg_task(key, n_workers=W)
    alg, solver = linreg_algorithm("afadmm", task)
    import dataclasses
    alg = dataclasses.replace(
        alg, acfg=dataclasses.replace(alg.acfg, flip_on_change=False),
        telemetry=True)
    with tempfile.TemporaryDirectory() as td:
        sink = MetricsSink(td)
        sink.write_manifest(run_manifest(bench="obs_microbench"))
        hist = train(alg, task.theta0, solver, task.grad_fn, 20,
                     jax.random.PRNGKey(1), eval_fn=task.eval_fn,
                     eval_every=10, driver="scan", sink=sink)
        sink.log_done(20, 0.0)
        sink.close()
        violations = validate_run_dir(td)
    out["sink_rounds_logged"] = 20
    out["sink_jsonl_violations"] = violations
    out["sink_jsonl_valid"] = not violations
    out["snr_db_series_finite"] = bool(
        np.all(np.isfinite(hist.extra["obs/rx_snr_db"])))
    # overhead bound, not a speedup: telemetry must be ~free when on and
    # bitwise absent when off
    out["optimised_metric"] = "telemetry_overhead_x"
    return out


# ---------------------------------------------------------------------------
# phy scenario engine: fused channel-step + masked receive
# ---------------------------------------------------------------------------

def phy_microbench() -> dict:
    """ISSUE 4 contract numbers: the Gauss–Markov channel step costs ONE
    fused Pallas dispatch per round at packed (W, D) scale (vs the ~6
    elementwise HLOs of the jnp reference) and matches it ≤ 1e-6; the
    masked receive matches both the jnp masked reference and the unmasked
    receive over the active subset (masked workers contribute exactly 0)."""
    from repro.core import cplx, transport
    from repro.core.channel import ChannelConfig, rayleigh
    from repro.phy.fading import gauss_markov_step
    from repro.phy.scenario import make_scenario

    W, d = 8, 1 << 16
    key = jax.random.PRNGKey(0)
    h = rayleigh(key, (W, d))
    rho = 0.9

    def step_pallas(hh):
        return gauss_markov_step(jax.random.fold_in(key, 1), hh, rho,
                                 jnp.asarray(True), backend="pallas")

    fad_dispatches = _count_pallas_dispatches(step_pallas, h)
    got = step_pallas(h)
    want = gauss_markov_step(jax.random.fold_in(key, 1), h, rho,
                             jnp.asarray(True), backend="jnp")
    fad_err = max(float(jnp.max(jnp.abs(got.re - want.re))),
                  float(jnp.max(jnp.abs(got.im - want.im))))

    # masked receive: parity + exact-zero contribution of masked workers
    k2 = jax.random.fold_in(key, 2)
    theta = jax.random.normal(k2, (W, d))
    lam = cplx.Complex(0.3 * jax.random.normal(jax.random.fold_in(k2, 1),
                                               (W, d)),
                       0.3 * jax.random.normal(jax.random.fold_in(k2, 2),
                                               (W, d)))
    mask = jnp.arange(W) % 3 != 0          # drop workers 0, 3, 6
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    kn = jax.random.fold_in(key, 3)
    T_j, _ = transport.ota_uplink(theta, lam, h, kn, 0.5, ccfg, mask=mask,
                                  backend="jnp")
    T_p, _ = transport.ota_uplink(theta, lam, h, kn, 0.5, ccfg, mask=mask,
                                  backend="pallas")
    idx = jnp.nonzero(mask)[0]
    sub = lambda c: cplx.Complex(c.re[idx], c.im[idx])
    T_s, _ = transport.ota_uplink(
        theta[idx], sub(lam), sub(h), kn, 0.5,
        ChannelConfig(n_workers=int(idx.size), noisy=True, snr_db=20.0),
        backend="jnp")
    masked_err = float(jnp.max(jnp.abs(T_p - T_j)))
    subset_err = float(jnp.max(jnp.abs(T_j - T_s)))

    # a full scenario round step (markov-doppler) at packed scale
    scn = make_scenario("markov-doppler", ccfg)
    st = scn.init(key, W, d)
    step_j = jax.jit(lambda s, k: scn.step(k, s))
    jax.block_until_ready(step_j(st, key))
    us = _time(lambda: jax.block_until_ready(step_j(st, key)))

    # wall-clock: composed masked round vs the one-pass fused round on the
    # same (W, d) planes — the scenario engine's per-round uplink cost
    comp_j = jax.jit(lambda t, l, hh, k: transport.ota_uplink(
        t, l, hh, k, 0.5, ccfg, mask=mask, backend="jnp")[0])
    fuse_j = jax.jit(lambda t, l, hh, k: transport.ota_round_fused(
        t, l, hh, k, 0.5, ccfg, mask=mask, backend="jnp")[0])
    comp_j(theta, lam, h, kn), fuse_j(theta, lam, h, kn)
    comp_us = _time(lambda: comp_j(theta, lam, h, kn).block_until_ready())
    fuse_us = _time(lambda: fuse_j(theta, lam, h, kn).block_until_ready())
    return {
        "shape": {"W": W, "d": d, "rho": rho},
        # the per-round channel-step cost: one fused kernel launch
        "channel_step_dispatches_per_round": fad_dispatches,
        "channel_step_max_err_vs_jnp": fad_err,
        "masked_receive_max_err_vs_jnp": masked_err,
        "masked_vs_active_subset_max_err": subset_err,
        "scenario_step_us_per_round_jnp": us,
        "participation": float(jnp.mean(mask)),
        "composed_masked_round_us": comp_us,
        "fused_masked_round_us": fuse_us,
        "speedup_fused_over_composed_masked_round": comp_us / fuse_us,
        # wall-clock contract field (bench methodology)
        "optimised_metric": "speedup_fused_over_composed_masked_round",
    }


def scaleup_microbench() -> dict:
    """ISSUE 10 contract: at N = 65536 the fused population phy step
    (``phy.population.population_step``, one jit) beats the pre-fusion
    hot path — the same ``correlated_step`` → ``waypoint_shadow_step`` →
    ``worker_gains`` chain issued as per-function eager jnp calls, one
    XLA dispatch per op, which is exactly how ``Scenario.step`` evolved
    the population before this module existed.  On the jnp backend the
    fused step IS that chain, so parity is bitwise.  Plus the structural
    pin behind it: a freq-flat mobile ``Scenario.step`` on the pallas
    backend is exactly ONE kernel launch for the whole phy (fading +
    mobility + shadowing + path gain)."""
    from repro.core.channel import ChannelConfig, rayleigh
    from repro.phy import (GeometryConfig, make_scenario, population_step)
    from repro.phy import fading as _fading
    from repro.phy import geometry as _geo

    n = 65536
    rho, coh = 0.95, 4
    key = jax.random.PRNGKey(0)
    gcfg = GeometryConfig(speed_mps=15.0, shadowing_sigma_db=6.0,
                          slot_seconds=1.0)
    kh, kp, ks, kf, kg = jax.random.split(key, 5)
    h = rayleigh(kh, (n, 1))
    pos, dest = _geo.init_positions(kp, n, gcfg)
    shadow = _geo.shadowing(ks, n, gcfg)
    age = jnp.zeros((), jnp.int32)

    fused = jax.jit(lambda h, age, pos, dest, shadow: population_step(
        kf, kg, h, age, pos, dest, shadow, gcfg, rho=rho,
        coherence_iters=coh, backend="jnp"))

    def composed():
        # deliberately NOT jitted: per-function eager dispatch is the
        # baseline the fused step replaces (op-by-op XLA executions)
        h2, age2, _ = _fading.correlated_step(kf, h, age, rho, coh,
                                              backend="jnp")
        p2, d2, s2 = _geo.waypoint_shadow_step(kg, pos, dest, shadow, gcfg)
        g = _geo.worker_gains(p2, s2, gcfg)
        jax.block_until_ready((h2, p2, g))
        return h2, age2, p2, d2, s2, g

    def composed_jit():
        # parity oracle: same chain under jit, so both sides see identical
        # XLA fusion/FMA decisions (eager vs jit can differ by an ulp,
        # enough to flip an `arrived` threshold and redraw a waypoint)
        h2, age2, _ = jax.jit(
            lambda h, age: _fading.correlated_step(
                kf, h, age, rho, coh, backend="jnp"))(h, age)
        p2, d2, s2 = jax.jit(
            lambda pos, dest, shadow: _geo.waypoint_shadow_step(
                kg, pos, dest, shadow, gcfg))(pos, dest, shadow)
        g = jax.jit(lambda pos, shadow: _geo.worker_gains(
            pos, shadow, gcfg))(p2, s2)
        jax.block_until_ready((h2, p2, g))
        return h2, age2, p2, d2, s2, g

    got = jax.block_until_ready(fused(h, age, pos, dest, shadow))
    want = composed_jit()
    parity = max(
        float(jnp.max(jnp.abs(got[0].re - want[0].re))),
        float(jnp.max(jnp.abs(got[0].im - want[0].im))),
        float(jnp.max(jnp.abs(got[2] - want[2]))),
        float(jnp.max(jnp.abs(got[4] - want[4]))),
        float(jnp.max(jnp.abs(got[5] - want[5]))))

    fused_us = _time(lambda: jax.block_until_ready(
        fused(h, age, pos, dest, shadow)))
    comp_us = _time(composed)

    # structural pin (trace only, backend-independent): the whole phy step
    # of a freq-flat mobile scenario is ONE pallas launch
    ccfg = ChannelConfig(n_workers=256)
    scn = make_scenario("urban-mobility", ccfg, freq_flat=True,
                        backend="pallas")
    st = scn.init(key, 256, 32)
    dispatches = _count_pallas_dispatches(lambda s, k: scn.step(k, s),
                                          st, key)
    return {
        "shape": {"N": n, "rho": rho, "coherence_iters": coh},
        "fused_population_step_us": fused_us,
        "composed_eager_chain_us": comp_us,
        "speedup_fused_over_composed": comp_us / fused_us,
        "parity_max_abs_err_jnp": parity,       # bitwise: fused IS the chain
        "scenario_step_pallas_dispatches": dispatches,
        "optimised_metric": "speedup_fused_over_composed",
    }


def device_microbench() -> dict:
    """Opt-in real-accelerator lane (closes ROADMAP item 1's leftover):
    ``REPRO_BENCH_DEVICE=gpu|tpu`` runs the pallas population step and the
    fused OTA round autotuners on the actual device; unset — or a platform
    mismatch (the usual CPU CI) — returns a clean skip marker instead of
    interpreting pallas kernels for hours."""
    import os
    want = os.environ.get("REPRO_BENCH_DEVICE", "").lower()
    plat = jax.default_backend()
    if not want:
        return {"skipped": True, "platform": plat,
                "reason": "REPRO_BENCH_DEVICE unset (opt-in lane)"}
    if plat != want:
        return {"skipped": True, "platform": plat,
                "reason": f"REPRO_BENCH_DEVICE={want} but jax platform "
                          f"is {plat}"}
    from repro.core.transport import autotune_ota_round
    from repro.phy import autotune_population_step
    pop = autotune_population_step(1 << 20, backend="pallas")
    rnd = autotune_ota_round(256, 1 << 16, backend="pallas")
    return {
        "skipped": False,
        "platform": plat,
        "population_step_1M": pop,
        "ota_round_256x65536": rnd,
        "optimised_metric": "population_step_1M.best.us",
    }


# ---------------------------------------------------------------------------
# flash attention forward + backward (custom_vjp) dispatch counts
# ---------------------------------------------------------------------------

def _count_pallas_dispatches(fn, *args) -> int:
    """Count pallas_call equations anywhere in ``fn``'s jaxpr (recursing
    into custom_vjp/scan/cond sub-jaxprs) — each is one kernel launch per
    call on TPU."""
    from jax.extend import core as jex_core

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                n += sum(walk(j) for j in _subjaxprs(v))
        return n

    def _subjaxprs(v):
        if isinstance(v, jex_core.ClosedJaxpr):
            return [v.jaxpr]
        if isinstance(v, jex_core.Jaxpr):
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for item in v for j in _subjaxprs(item)]
        return []

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def attn_bwd_microbench() -> dict:
    """Fwd + bwd kernel dispatch counts and grad parity of the custom_vjp
    flash attention (ISSUE 3): the grad path must cost exactly 3 kernel
    launches — 1 forward (o + lse residual) + 2 backward (dq; dk/dv) — with
    no (S,S) tensor materialised and cotangents within 1e-5 of the jnp
    oracle."""
    from repro.kernels import flash_attention as fa
    from repro.kernels import ref

    B, H, S, hd = 2, 4, 256, 64
    bq = bk = 128
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, H, S, hd))
               for i in range(3))

    def f(q, k, v):
        return fa.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)

    def loss(q, k, v):
        return jnp.sum(jnp.sin(f(q, k, v)))

    fwd_n = _count_pallas_dispatches(f, q, k, v)
    total_n = _count_pallas_dispatches(
        jax.grad(loss, argnums=(0, 1, 2)), q, k, v)

    grad_j = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
    got = grad_j(q, k, v)
    naive_grad = jax.jit(jax.grad(lambda *a: jnp.sum(jnp.sin(
        ref.attention(*a, causal=True))), argnums=(0, 1, 2)))
    want = naive_grad(q, k, v)
    errs = {f"max_abs_err_d{n}": float(jnp.max(jnp.abs(g - w)))
            for n, g, w in zip("qkv", got, want)}
    us = _time(lambda: jax.block_until_ready(grad_j(q, k, v)), iters=3)
    naive_us = _time(lambda: jax.block_until_ready(naive_grad(q, k, v)),
                     iters=3)
    return {
        "shape": {"B": B, "H": H, "S": S, "hd": hd,
                  "block_q": bq, "block_k": bk},
        # kernel launches in the lowered HLO: 1 fwd; grad = fwd-with-residual
        # + dq kernel + dk/dv kernel
        "fwd_dispatches": fwd_n,
        "grad_total_dispatches": total_n,
        "bwd_dispatches": total_n - fwd_n,
        # residual saved beyond the primals: one f32 (B,H,S) lse plane
        "residual_lse_bytes": B * H * S * 4,
        # what the naive jnp backward would materialise instead
        "naive_bwd_score_tensor_bytes": B * H * S * S * 4,
        "interpret_grad_us_per_call": us,
        "naive_jnp_grad_us_per_call": naive_us,
        # Wall-clock contract field (bench methodology).  On this CPU the
        # kernel executes INTERPRETED, so the ratio is << 1 here by
        # construction; the production (TPU) signal is the pinned dispatch
        # counts + the (S,S)-tensor-free residual above.
        "speedup_flash_grad_over_naive": naive_us / us,
        "optimised_metric": "speedup_flash_grad_over_naive",
        **errs,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write transport benchmark JSON to this path")
    ap.add_argument("--out-packed", default=None,
                    help="write the packed-vs-per-leaf uplink JSON to this "
                         "path (BENCH_packed.json)")
    ap.add_argument("--packed-only", action="store_true",
                    help="skip the kernel/transport sections (CI smoke)")
    ap.add_argument("--attn-bwd", action="store_true",
                    help="flash-attention fwd+bwd dispatch-count / grad "
                         "parity section only (CI smoke)")
    ap.add_argument("--out-attn-bwd", default="BENCH_attn_bwd.json",
                    help="where --attn-bwd writes its JSON")
    ap.add_argument("--phy", action="store_true",
                    help="phy scenario-engine section only: fused "
                         "channel-step dispatch count + masked-receive "
                         "parity (CI smoke)")
    ap.add_argument("--out-phy", default="BENCH_phy.json",
                    help="where --phy writes its JSON")
    ap.add_argument("--fused-round", action="store_true",
                    help="fused one-pass OTA round section only: wall-clock "
                         "fused vs composed-packed vs leafwise + W=256 "
                         "cohort stream (CI smoke)")
    ap.add_argument("--out-fused-round", default="BENCH_fused_round.json",
                    help="where --fused-round writes its JSON")
    ap.add_argument("--faults", action="store_true",
                    help="fault-guard section only: guarded-vs-unguarded "
                         "healthy-round overhead (bitwise parity) + "
                         "25%%-crash/NaN chaos smoke (CI smoke)")
    ap.add_argument("--out-faults", default="BENCH_faults.json",
                    help="where --faults writes its JSON")
    ap.add_argument("--shard-local", action="store_true",
                    help="shard-local packed uplink section only: 2-shard "
                         "model-parallel mesh, 1 receive/shard/round + "
                         "bitwise leafwise parity (CI smoke).  Forces a "
                         "2-device CPU platform, so it must run alone.")
    ap.add_argument("--out-shard-local", default="BENCH_shard_local.json",
                    help="where --shard-local writes its JSON")
    ap.add_argument("--sketched", action="store_true",
                    help="sketched A-FADMM-CS section only: one fused "
                         "receive per shard per sketched round on a "
                         "(data, fsdp, model) mesh + wall-clock vs the "
                         "full-dim replicated round (CI smoke).  Forces a "
                         "4-device CPU platform, so it must run alone.")
    ap.add_argument("--out-sketched", default="BENCH_sketch.json",
                    help="where --sketched writes its JSON")
    ap.add_argument("--obs", action="store_true",
                    help="observability section only: telemetry-on vs bare "
                         "fused-round overhead (bitwise parity) + "
                         "MetricsSink JSONL schema smoke (CI smoke)")
    ap.add_argument("--out-obs", default="BENCH_obs.json",
                    help="where --obs writes its JSON")
    ap.add_argument("--scaleup", action="store_true",
                    help="population-scale phy section only: fused "
                         "one-dispatch population step vs the composed "
                         "3-jit chain at N=65536 (>=1.0x gated in CI) + "
                         "the 1-launch freq-flat Scenario.step pin")
    ap.add_argument("--out-scaleup", default="BENCH_scaleup_micro.json",
                    help="where --scaleup writes its JSON")
    ap.add_argument("--device-bench", action="store_true",
                    help="opt-in real-accelerator lane: honours "
                         "REPRO_BENCH_DEVICE=gpu|tpu, self-skips cleanly "
                         "on CPU / unset (no file written when skipped)")
    ap.add_argument("--out-device-bench", default="BENCH_device.json",
                    help="where --device-bench writes its JSON (skipped "
                         "runs print the skip marker and write nothing)")
    args = ap.parse_args()
    if args.shard_local or args.sketched:
        # must happen before jax's first backend init (the import above is
        # fine — jax locks the device count at first use, not import)
        import os
        n = 4 if args.sketched else 2
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()
    derived = {}
    if not (args.packed_only or args.attn_bwd or args.phy
            or args.shard_local or args.fused_round or args.faults
            or args.sketched or args.obs or args.scaleup
            or args.device_bench):
        derived = {"kernels": microbench(),
                   "transport": transport_microbench()}
    out = dict(derived)
    # the packed bench builds+compiles a reduced transformer twice — only
    # pay for it when asked (CI runs it as its own --packed-only step)
    if args.packed_only or args.out_packed:
        out["packed_uplink"] = packed_microbench()
    if args.attn_bwd:
        out["attn_bwd"] = attn_bwd_microbench()
    if args.phy:
        out["phy"] = phy_microbench()
    if args.fused_round:
        out["fused_round"] = fused_round_microbench()
    if args.faults:
        out["faults"] = faults_microbench()
    if args.shard_local:
        out["shard_local"] = shard_local_microbench()
    if args.sketched:
        out["sketched"] = sketched_microbench()
    if args.obs:
        out["obs"] = obs_microbench()
    if args.scaleup:
        out["scaleup"] = scaleup_microbench()
    if args.device_bench:
        out["device"] = device_microbench()
    text = json.dumps(out, indent=2, default=str)
    print(text)
    if args.out and derived:
        with open(args.out, "w") as f:
            f.write(json.dumps(derived, indent=2, default=str) + "\n")
    if args.out_packed:
        with open(args.out_packed, "w") as f:
            f.write(json.dumps(out["packed_uplink"], indent=2, default=str)
                    + "\n")
    if args.attn_bwd:
        with open(args.out_attn_bwd, "w") as f:
            f.write(json.dumps(out["attn_bwd"], indent=2, default=str) + "\n")
    if args.phy:
        with open(args.out_phy, "w") as f:
            f.write(json.dumps(out["phy"], indent=2, default=str) + "\n")
    if args.fused_round:
        with open(args.out_fused_round, "w") as f:
            f.write(json.dumps(out["fused_round"], indent=2, default=str)
                    + "\n")
    if args.faults:
        with open(args.out_faults, "w") as f:
            f.write(json.dumps(out["faults"], indent=2, default=str) + "\n")
    if args.shard_local:
        with open(args.out_shard_local, "w") as f:
            f.write(json.dumps(out["shard_local"], indent=2, default=str)
                    + "\n")
    if args.sketched:
        with open(args.out_sketched, "w") as f:
            f.write(json.dumps(out["sketched"], indent=2, default=str) + "\n")
    if args.obs:
        with open(args.out_obs, "w") as f:
            f.write(json.dumps(out["obs"], indent=2, default=str) + "\n")
    if args.scaleup:
        with open(args.out_scaleup, "w") as f:
            f.write(json.dumps(out["scaleup"], indent=2, default=str) + "\n")
    if args.device_bench and not out["device"].get("skipped"):
        with open(args.out_device_bench, "w") as f:
            f.write(json.dumps(out["device"], indent=2, default=str) + "\n")


if __name__ == "__main__":
    main()
