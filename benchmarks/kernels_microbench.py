"""Microbenchmark: Pallas kernels (interpret mode) vs jnp reference.

On CPU this measures the *reference* path's wall time (the kernels execute
interpreted, so wall time is not meaningful for them); the derived numbers
report correctness deltas + the per-element HBM-traffic model that motivates
the fusion (DESIGN.md §6).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

N = 1 << 20


def microbench():
    k = jax.random.PRNGKey(0)
    args = [jax.random.normal(jax.random.fold_in(k, i), (N,))
            for i in range(5)]

    want = ref.ota_modulate(*args, 0.5)
    got = ops.ota_modulate(*args, 0.5)
    mod_err = float(jnp.max(jnp.abs(got[0] - want[0])))

    ref_j = jax.jit(lambda *a: ref.ota_modulate(*a, 0.5))
    ref_j(*args)[0].block_until_ready()
    t0 = time.time()
    for _ in range(10):
        ref_j(*args)[0].block_until_ready()
    ref_us = (time.time() - t0) / 10 * 1e6

    # HBM-traffic model (bytes/element): naive = 5 reads + 2 writes per plane
    # with ~3 intermediate materialisations; fused = 5 reads + 2 writes.
    naive_traffic = (5 + 2 + 6) * 4
    fused_traffic = (5 + 2) * 4
    return {
        "n_elements": N,
        "modulate_max_err_vs_ref": mod_err,
        "ref_jit_us_per_call": ref_us,
        "traffic_bytes_per_elem_naive": naive_traffic,
        "traffic_bytes_per_elem_fused": fused_traffic,
        "predicted_fusion_speedup": naive_traffic / fused_traffic,
    }
