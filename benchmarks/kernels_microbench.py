"""Microbenchmark: Pallas kernels (interpret mode) vs jnp reference, plus
the transport-layer benchmarks (fused OTA uplink, loop-vs-scan trainer).

On CPU this measures the *reference* path's wall time (the kernels execute
interpreted, so wall time is not meaningful for them); the derived numbers
report correctness deltas + the per-element HBM-traffic model that motivates
the fusion (DESIGN.md §6).  The loop-vs-scan trainer numbers ARE meaningful
on CPU: they measure the Python-dispatch + host-sync overhead the scan
driver removes, which is backend-independent.

    PYTHONPATH=src python -m benchmarks.kernels_microbench \
        --out BENCH_transport.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

N = 1 << 20


def _time(fn, iters: int = 10) -> float:
    """Wall time per call in µs (post-warmup)."""
    fn()
    t0 = time.time()
    for _ in range(iters):
        fn()
    return (time.time() - t0) / iters * 1e6


def microbench():
    k = jax.random.PRNGKey(0)
    args = [jax.random.normal(jax.random.fold_in(k, i), (N,))
            for i in range(5)]

    want = ref.ota_modulate(*args, 0.5)
    got = ops.ota_modulate(*args, 0.5)
    mod_err = float(jnp.max(jnp.abs(got[0] - want[0])))

    ref_j = jax.jit(lambda *a: ref.ota_modulate(*a, 0.5))
    ref_us = _time(lambda: ref_j(*args)[0].block_until_ready())

    # HBM-traffic model (bytes/element): naive = 5 reads + 2 writes per plane
    # with ~3 intermediate materialisations; fused = 5 reads + 2 writes.
    naive_traffic = (5 + 2 + 6) * 4
    fused_traffic = (5 + 2) * 4
    return {
        "n_elements": N,
        "modulate_max_err_vs_ref": mod_err,
        "ref_jit_us_per_call": ref_us,
        "traffic_bytes_per_elem_naive": naive_traffic,
        "traffic_bytes_per_elem_fused": fused_traffic,
        "predicted_fusion_speedup": naive_traffic / fused_traffic,
    }


# ---------------------------------------------------------------------------
# transport layer: fused uplink + loop-vs-scan round driver
# ---------------------------------------------------------------------------

def _uplink_case(W: int, d: int, label: str) -> dict:
    """Fused-OTA round time, jnp vs pallas backend, at one model scale."""
    from repro.core import cplx, transport
    from repro.core.channel import ChannelConfig, rayleigh

    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = cplx.Complex(0.3 * jax.random.normal(k2, (W, d)),
                       0.3 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))
    ccfg = ChannelConfig(n_workers=W, noisy=True)

    def up(backend):
        return jax.jit(lambda t, l, hh, kk: transport.ota_uplink(
            t, l, hh, kk, 0.5, ccfg, backend=backend)[0])

    out = {"label": label, "W": W, "d": d}
    ref_theta = None
    for backend in ("jnp", "pallas"):
        f = up(backend)
        theta_out = f(theta, lam, h, key)
        if ref_theta is None:
            ref_theta = theta_out
        else:
            out["max_abs_err_vs_jnp"] = float(
                jnp.max(jnp.abs(theta_out - ref_theta)))
        out[f"{backend}_us_per_round"] = _time(
            lambda f=f: f(theta, lam, h, key).block_until_ready())
    # elementwise HLO count the fusion collapses (modulate, scale, mul, sum,
    # noise-add, div, eps-max -> one kernel): traffic model as above.
    out["hbm_passes_unfused"] = 5
    out["hbm_passes_fused"] = 1
    return out


def _trainer_case(n_rounds: int, eval_every: int) -> dict:
    """Python-loop vs scan-compiled driver on the paper's linreg task.

    Two numbers per driver:

    * ``*_seconds_end_to_end`` — one cold ``train`` call (includes trace +
      compile: what a one-shot figure run actually pays).
    * ``compiled_dispatch`` — the already-compiled round/chunk functions
      dispatched back-to-back with no Python re-tracing and no host pulls:
      isolates the per-round dispatch overhead the scan driver removes
      (n dispatches vs n/coherence).
    """
    from benchmarks.common import (LINREG_WORKERS, linreg_algorithm,
                                   make_linreg_task)
    from repro.train import train

    key = jax.random.PRNGKey(0)
    task = make_linreg_task(key)
    alg, solver = linreg_algorithm("afadmm", task)
    block = alg.ccfg.coherence_iters

    out = {"n_rounds": n_rounds, "workers": LINREG_WORKERS,
           "coherence_iters": block}
    hist = {}
    for driver in ("loop", "scan"):
        t0 = time.time()
        hist[driver] = train(alg, task.theta0, solver, task.grad_fn,
                             n_rounds, jax.random.PRNGKey(1),
                             eval_fn=task.eval_fn, eval_every=eval_every,
                             driver=driver)
        out[f"{driver}_seconds_end_to_end"] = time.time() - t0
    out["speedup_scan_over_loop_end_to_end"] = \
        out["loop_seconds_end_to_end"] / out["scan_seconds_end_to_end"]

    st = alg.init(jax.random.PRNGKey(1), task.theta0)
    round_j = jax.jit(lambda s, k: alg.round(k, s, solver, task.grad_fn))
    chunk_j = jax.jit(lambda s, rs: alg.scan_rounds(
        jax.random.PRNGKey(1), s, solver, task.grad_fn, rs))
    rs = jnp.arange(block, dtype=jnp.int32)
    jax.block_until_ready(round_j(st, key))           # compile
    jax.block_until_ready(chunk_j(st, rs))

    # both branches execute exactly n_eff rounds so the speedup compares
    # equal work even when the coherence block doesn't divide n_rounds
    n_chunks = n_rounds // block
    n_eff = n_chunks * block
    t0 = time.time()
    s = st
    for r in range(n_eff):
        s, _ = round_j(s, jax.random.fold_in(key, r))
    jax.block_until_ready(s)
    t_loop = time.time() - t0
    t0 = time.time()
    s = st
    for c in range(n_chunks):
        s, _ = chunk_j(s, rs + c * block)
    jax.block_until_ready(s)
    t_scan = time.time() - t0
    out["compiled_dispatch"] = {
        "n_rounds_timed": n_eff,
        "loop_n_dispatches": n_eff, "loop_seconds": t_loop,
        "scan_n_dispatches": n_chunks, "scan_seconds": t_scan,
        "speedup_scan_over_loop": t_loop / t_scan,
    }

    out["history_bitwise_equal"] = bool(
        hist["loop"].loss == hist["scan"].loss
        and hist["loop"].channel_uses == hist["scan"].channel_uses)
    return out


def transport_microbench():
    from benchmarks.common import MLP_WORKERS, make_mlp_task

    d_mlp = int(make_mlp_task(jax.random.PRNGKey(0)).d)
    return {
        "uplink_linreg": _uplink_case(10, 6, "linreg (paper Sec. 5)"),
        "uplink_mlp": _uplink_case(MLP_WORKERS, d_mlp, "MLP (FAST scale)"),
        # eval_every=1 is the figure benchmarks' cadence (one eval host
        # sync per round in the loop driver — the worst case scan removes).
        # One trainer case only: a second one in the same process would
        # have its end-to-end timing skewed by XLA executable-cache hits
        # from the first.
        "trainer_linreg_300r": _trainer_case(300, eval_every=1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write transport benchmark JSON to this path")
    args = ap.parse_args()
    derived = {"kernels": microbench(), "transport": transport_microbench()}
    text = json.dumps(derived, indent=2, default=str)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
