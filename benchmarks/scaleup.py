"""Log-scale worker sweep: how far does one OTA round scale?

Full-transmit rounds at W ∈ {16, 256, 4096, 65536} plus a
1M-population / 256-cohort sampled round (``core.cohort``), all running
the SAME flat A-FADMM round over the freq-flat ``urban-mobility``
scenario — so the fused population phy step (``phy.population``) and the
packed transport are what is actually being scaled.  Per sweep point:

* ``seconds_per_round``   wall-clock, median of ``--iters`` jitted rounds
* ``rx_snr_db``           in-graph receive SNR (``obs/`` telemetry)
* ``consensus_gap_*``     RMS ‖θ_n − Θ‖ before/after ``--rounds`` rounds

plus the structural pin behind the 1M point: a jaxpr walk of the sampled
round proving no COMPUTE intermediate reaches O(N·D) — population-width
buffers may only appear as carried state, phy planes (O(N)), and
gather/scatter row traffic, so peak signal memory is O(cohort·D)
regardless of N.

    PYTHONPATH=src python benchmarks/scaleup.py [--fast] \
        [--out BENCH_scaleup.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import AdmmConfig, ChannelConfig, SubcarrierPlan, cplx
from repro.core import transport as _transport
from repro.core.aggregators import AFadmm
from repro.core.cohort import CohortConfig
from repro.phy import make_scenario

D = 32          #: model dim — small on purpose: the sweep scales WORKERS
N_SUB = 32
RHO = 0.5
SNR_DB = 20.0

#: (population, cohort) sweep; cohort == population -> everyone transmits
SWEEP = ((16, 16), (256, 256), (4096, 4096), (65536, 65536),
         (1_000_000, 256))
SWEEP_FAST = ((16, 16), (64, 64), (256, 32))

#: buffer-restructuring primitives — moving existing bytes, not creating
#: live compute intermediates (same convention as tests/test_fused_round);
#: gather/scatter are the cohort row traffic, scatter also the population
#: state writeback
_LAYOUT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "slice", "concatenate", "pad", "copy", "dynamic_slice",
    "dynamic_update_slice", "gather", "scatter", "scatter-add",
}


def proximal_solver(rho: float):
    """Closed-form primal for the proximal-point objective
    f_n(θ) = ‖θ − θ_n^prev‖²: a data-free consensus task whose solver is
    width-agnostic (works at population AND gathered-cohort width).

    Stationarity: 2(θ − θ_prev) + Re{λ*h} + ρ|h|²(θ − Θ) = 0."""
    def solve(theta, lam, h, Theta):
        h2 = cplx.abs2(h)
        mu = cplx.cmul_conj(h, lam).re
        return (2.0 * theta - mu + rho * h2 * Theta[None, :]) \
            / (2.0 + rho * h2)
    return solve


def _zero_grad(theta):
    return jnp.zeros_like(theta)


def make_alg(population: int, cohort: int):
    acfg = AdmmConfig(rho=RHO, flip_on_change=False, power_control=True)
    ccfg = ChannelConfig(n_workers=population, n_subcarriers=N_SUB,
                         snr_db=SNR_DB)
    plan = SubcarrierPlan.build(D, N_SUB)
    scn = make_scenario("urban-mobility", ccfg, freq_flat=True)
    coh = CohortConfig(population=population, cohort=cohort) \
        if cohort < population else None
    return AFadmm(acfg, ccfg, plan, scenario=scn, telemetry=True,
                  cohort=coh)


def max_compute_out_elems(fn, *args) -> int:
    """Largest output aval (elements) of any non-layout equation in
    ``fn``'s jaxpr, recursing into scan/cond/pjit bodies.  Pure trace —
    nothing executes, so it is safe at N = 10⁶ and beyond."""
    from jax.extend import core as jcore
    worst = 0

    def walk(j):
        nonlocal worst
        for eqn in j.eqns:
            for v in eqn.params.values():
                if isinstance(v, jcore.ClosedJaxpr):
                    walk(v.jaxpr)
                elif isinstance(v, jcore.Jaxpr):
                    walk(v)
            if eqn.primitive.name in _LAYOUT_PRIMS or any(
                    isinstance(v, (jcore.ClosedJaxpr, jcore.Jaxpr))
                    for v in eqn.params.values()):
                continue
            for ov in eqn.outvars:
                worst = max(worst, ov.aval.size)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return worst


def run_point(population: int, cohort: int, rounds: int, iters: int,
              seed: int = 0) -> dict:
    alg = make_alg(population, cohort)
    solve = proximal_solver(RHO)
    key = jax.random.PRNGKey(seed)
    theta0 = jax.random.normal(jax.random.fold_in(key, 1),
                               (population, D), jnp.float32)
    st = alg.init(key, theta0)

    gap = lambda s: float(jnp.sqrt(jnp.mean(
        (s.theta - s.Theta[None, :]) ** 2)))
    gap0 = gap(st)

    round_fn = jax.jit(
        lambda s, k: alg.round(k, s, solve, _zero_grad))
    st1, metrics = jax.tree.map(jax.block_until_ready, round_fn(st, key))

    ts = []
    for i in range(iters):
        k = jax.random.fold_in(key, 100 + i)
        t0 = time.perf_counter()
        jax.block_until_ready(round_fn(st1, k)[0])
        ts.append(time.perf_counter() - t0)
    ts.sort()

    stN, _ = alg.scan_rounds(key, st, solve, _zero_grad, rounds)
    stN = jax.block_until_ready(stN)

    return {
        "workers": int(cohort),
        "population": int(population),
        "cohort": int(cohort),
        "sampled": cohort < population,
        "rounds": int(rounds),
        "seconds_per_round": ts[len(ts) // 2],
        "rx_snr_db": float(metrics["obs/rx_snr_db"]),
        "consensus_gap_first": gap0,
        "consensus_gap_last": gap(stN),
        "optimised_metric": "seconds_per_round",
    }


def memory_pin(population: int, cohort: int) -> dict:
    """Structural O(cohort·D) claim on the SAMPLED round at full N."""
    alg = make_alg(population, cohort)
    solve = proximal_solver(RHO)
    key = jax.random.PRNGKey(0)
    st = jax.eval_shape(
        lambda k: alg.init(k, jnp.zeros((population, D), jnp.float32)), key)
    st = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype)
        if hasattr(s, "shape") else s, st)
    worst = max_compute_out_elems(
        lambda s, k: alg.round(k, s, solve, _zero_grad)[0], st, key)
    # allowed: O(cohort·D) signal planes plus O(N) phy/mask/dual-index
    # planes; an (N, D)-sized compute intermediate (= the thing cohort
    # sampling exists to avoid) would need population*D elements
    bound = max(16 * cohort * D, 8 * population)
    return {
        "population": int(population),
        "cohort": int(cohort),
        "d": D,
        "max_compute_out_elems": int(worst),
        "bound_elems": int(bound),
        "n_times_d_elems": int(population * D),
        "ok": bool(worst <= bound and worst < population * D),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scaleup.json")
    ap.add_argument("--rounds", type=int, default=12,
                    help="convergence rounds per sweep point")
    ap.add_argument("--iters", type=int, default=5,
                    help="timed round repetitions (median reported)")
    ap.add_argument("--fast", action="store_true",
                    help="tiny sweep for CI/smoke (shape-identical JSON)")
    args = ap.parse_args(argv)

    sweep_pts = SWEEP_FAST if args.fast else SWEEP
    sweep = {}
    for population, cohort in sweep_pts:
        name = f"W{cohort}" if cohort == population \
            else f"N{population}_c{cohort}"
        t0 = time.time()
        sweep[name] = run_point(population, cohort, args.rounds, args.iters)
        print(f"{name}: {sweep[name]['seconds_per_round'] * 1e3:.2f} "
              f"ms/round  rx_snr={sweep[name]['rx_snr_db']:.1f} dB  "
              f"gap {sweep[name]['consensus_gap_first']:.3f} -> "
              f"{sweep[name]['consensus_gap_last']:.3f}  "
              f"({time.time() - t0:.1f}s)", flush=True)

    pin_pop, pin_coh = sweep_pts[-1] if args.fast else SWEEP[-1]
    pin = memory_pin(pin_pop, pin_coh)
    print(f"memory pin: worst compute out {pin['max_compute_out_elems']} "
          f"elems <= bound {pin['bound_elems']} "
          f"(N*D = {pin['n_times_d_elems']}): "
          f"{'OK' if pin['ok'] else 'VIOLATED'}", flush=True)

    out = {
        "config": {"d": D, "n_subcarriers": N_SUB, "rho": RHO,
                   "snr_db": SNR_DB, "scenario": "urban-mobility/freq-flat",
                   "transport_backend": _transport.resolve_backend(None),
                   "device_backend": jax.default_backend(),
                   "rounds": args.rounds, "iters": args.iters,
                   "fast": bool(args.fast)},
        "sweep": sweep,
        "memory_pin": pin,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0 if pin["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
