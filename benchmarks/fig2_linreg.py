"""Paper Fig. 2 — linear regression over the simulated wireless channel.

(a) communication efficiency: loss vs # uploads, A-FADMM vs D-FADMM vs
    D-FADMM-10x vs A-GD (truncated channel inversion);
(b) energy efficiency: final loss vs SNR under a channel-use budget;
(c) scalability: channel uses to reach a target loss vs # workers.
"""
from __future__ import annotations

import jax

from benchmarks.common import (LINREG_ROUNDS, linreg_algorithm,
                               make_linreg_task)
from benchmarks.common import run_train as train  # scan/loop via env knob

KEY = jax.random.PRNGKey(0)


def fig2a_comm_efficiency(rounds: int = LINREG_ROUNDS):
    """loss-vs-uploads curves. Derived: uploads each algorithm needs to hit
    the paper's 1e-4 target (A-FADMM lowest; A-GD stalls)."""
    task = make_linreg_task(KEY)
    out = {}
    for name, n_sub, extra in [("afadmm", 10, None),
                               ("dfadmm", 10, None),
                               ("dfadmm-10x", 100, None),
                               ("analog_gd", 10,
                                dict(learning_rate=1e-2, epsilon=1e-6))]:
        alg, solver = linreg_algorithm(name.split("-")[0], task,
                                       n_sub=n_sub, extra=extra)
        hist = train(alg, task.theta0, solver, task.grad_fn, rounds,
                     jax.random.fold_in(KEY, 1), eval_fn=task.eval_fn)
        target = 1e-4
        idx = next((i for i, l in enumerate(hist.loss) if l < target), None)
        cum = hist.cumulative_uses()
        out[name] = {"final_loss": hist.loss[-1],
                     "rounds_to_1e-4": None if idx is None else idx + 1,
                     "channel_uses_to_1e-4":
                         None if idx is None else cum[idx]}
    return out


def fig2b_energy(budget_uses: float = 300.0,
                 snrs=(-10.0, 0.0, 10.0, 20.0, 40.0)):
    """Paper Fig 2(b): loss at a FIXED total channel-use budget vs SNR.

    A-FADMM spends 1 use/round regardless of SNR; D-FADMM's uses/round grow
    as the Shannon rate drops, so at low SNR it completes far fewer rounds —
    the paper's energy-efficiency crossover."""
    task = make_linreg_task(KEY)
    out = {}
    for snr in snrs:
        row = {}
        for name in ("afadmm", "dfadmm"):
            alg, solver = linreg_algorithm(name, task, snr_db=snr)
            hist = train(alg, task.theta0, solver, task.grad_fn,
                         LINREG_ROUNDS, jax.random.fold_in(KEY, 2),
                         eval_fn=task.eval_fn)
            cum = hist.cumulative_uses()
            idx = max((i for i, c in enumerate(cum) if c <= budget_uses),
                      default=0)
            row[name] = hist.loss[min(idx, len(hist.loss) - 1)]
            row[name + "_rounds_in_budget"] = idx + 1
        out[f"snr_{snr:g}dB"] = row
    return out


def fig2c_scalability(workers=(5, 10, 20), target: float = 1e-3):
    """channel uses until target loss vs number of workers."""
    out = {}
    for W in workers:
        task = make_linreg_task(jax.random.fold_in(KEY, W), n_workers=W)
        row = {}
        for name in ("afadmm", "dfadmm"):
            alg, solver = linreg_algorithm(name, task, snr_db=40.0)
            hist = train(alg, task.theta0, solver, task.grad_fn,
                         LINREG_ROUNDS, jax.random.fold_in(KEY, 3),
                         eval_fn=task.eval_fn)
            cum = hist.cumulative_uses()
            idx = next((i for i, l in enumerate(hist.loss) if l < target),
                       None)
            row[name] = cum[idx] if idx is not None else float("inf")
        out[f"W={W}"] = row
    return out
