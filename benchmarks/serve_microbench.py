"""Serving throughput microbenchmark: batched greedy decode on reduced
variants (CPU wall-clock; establishes the serve_step works end-to-end per
family and gives a relative cost ranking)."""
from __future__ import annotations

import time

import jax

from repro.models import get_model
from repro.serve import generate

ARCHS = ("granite-8b", "falcon-mamba-7b", "recurrentgemma-2b",
         "qwen3-moe-30b-a3b")


def serve_microbench(batch: int = 4, new_tokens: int = 12):
    key = jax.random.PRNGKey(0)
    out = {}
    for arch in ARCHS:
        m = get_model(arch, reduced=True)
        params = m.init(key)
        prompts = jax.random.randint(key, (batch, 4), 0, m.cfg.vocab_size)
        # warm-up compile
        generate(m, params, prompts, n_steps=1, max_seq=4 + new_tokens)
        t0 = time.time()
        toks = generate(m, params, prompts, n_steps=new_tokens,
                        max_seq=4 + new_tokens)
        dt = time.time() - t0
        out[arch] = {"tok_per_s": round(batch * new_tokens / dt, 1),
                     "shape_ok": list(toks.shape) == [batch, new_tokens]}
    return out
