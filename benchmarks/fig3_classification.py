"""Paper Fig. 3 — image classification with the 784-128-64-10 MLP
(A-SFADMM / D-SFADMM / A-SGD stochastic variants).

(a) test accuracy vs # uploads; (b) accuracy vs SNR; (c) channel uses to a
target accuracy vs # workers.
"""
from __future__ import annotations

import jax

from benchmarks.common import (MLP_ROUNDS, make_mlp_task, mlp_algorithm)
from benchmarks.common import run_train as train  # scan/loop via env knob

KEY = jax.random.PRNGKey(1)


def fig3a_comm_efficiency(rounds: int = MLP_ROUNDS):
    task = make_mlp_task(KEY)
    out = {}
    for name, kw in [("afadmm", {}),
                     ("dfadmm", {}),
                     ("analog_gd", dict(extra=dict(learning_rate=5e-2,
                                                   epsilon=1e-6)))]:
        alg = mlp_algorithm(name, task, **kw)
        hist = train(alg, task.theta0, task.solver, task.grad_fn, rounds,
                     jax.random.fold_in(KEY, 1), eval_fn=task.eval_fn,
                     eval_every=max(rounds // 5, 1))
        out["A-S" + name.upper() if name == "afadmm" else name] = {
            "final_accuracy": hist.accuracy[-1],
            "uploads": sum(hist.channel_uses) / max(hist.channel_uses[0], 1),
        }
    return out


def fig3b_energy(snrs=(-10.0, 10.0, 40.0), rounds: int = MLP_ROUNDS):
    task = make_mlp_task(KEY)
    W = task.theta0.shape[0]
    out = {}
    for snr in snrs:
        row = {}
        for name in ("afadmm", "dfadmm"):
            alg = mlp_algorithm(name, task, snr_db=snr)
            n_rounds = rounds if name == "afadmm" else max(rounds // 4, 3)
            hist = train(alg, task.theta0, task.solver, task.grad_fn,
                         n_rounds, jax.random.fold_in(KEY, 2),
                         eval_fn=task.eval_fn,
                         eval_every=max(n_rounds - 1, 1))
            row[name] = hist.accuracy[-1]
        out[f"snr_{snr:g}dB"] = row
    return out


def fig3c_scalability(workers=(5, 10), target_acc: float = 0.5,
                      rounds: int = MLP_ROUNDS):
    out = {}
    for W in workers:
        task = make_mlp_task(jax.random.fold_in(KEY, W), n_workers=W)
        row = {}
        for name in ("afadmm", "dfadmm"):
            alg = mlp_algorithm(name, task)
            hist = train(alg, task.theta0, task.solver, task.grad_fn,
                         rounds, jax.random.fold_in(KEY, 3),
                         eval_fn=task.eval_fn)
            cum = hist.cumulative_uses()
            idx = next((i for i, a in enumerate(hist.accuracy)
                        if a > target_acc), None)
            row[name] = cum[idx] if idx is not None else float("inf")
        out[f"W={W}"] = row
    return out
