"""Beyond-paper ablation: non-IID (Dirichlet) federated data.

The paper's experiments use equal IID shards. Under label-skewed shards the
per-worker optima genuinely disagree; ADMM's dual variables absorb the
disagreement, so A-FADMM should retain accuracy where plain analog gradient
averaging degrades. Reported: test accuracy after a fixed round budget, IID
vs Dirichlet(0.3), for A-FADMM and A-GD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import MLP_IMG_DIM, MLP_SIZES, MLP_SUBCARRIERS
from repro.core import AdmmConfig, ChannelConfig, SubcarrierPlan, make
from repro.data.federated import make_batch_fn, split_dirichlet, split_iid
from repro.data.synthetic import image_dataset
from repro.models.mlp import init_mlp_flat, make_loss_fns
from repro.optim import adam
from repro.optim.local_solvers import prox_adam_solver
from benchmarks.common import run_train as train  # scan/loop via env knob

KEY = jax.random.PRNGKey(7)


def _task(split: str, n_workers: int = 8, rho: float = 0.5):
    n_train, n_test = 4000, 800
    xtr, ytr, xte, yte = image_dataset(KEY, n_train, n_test, dim=MLP_IMG_DIM,
                                       cluster_std=3.0)
    if split == "iid":
        shards = split_iid(jax.random.fold_in(KEY, 1), n_train, n_workers)
    else:
        shards = split_dirichlet(jax.random.fold_in(KEY, 1), ytr, n_workers,
                                 alpha=0.3)
    flat0, unflatten = init_mlp_flat(jax.random.fold_in(KEY, 2), MLP_SIZES)
    d = int(flat0.shape[0])
    loss, grad, acc = make_loss_fns(unflatten)
    batch_fn = make_batch_fn((xtr, ytr), shards, batch_size=64)
    ctr = {"i": 0}

    def grad_fn(theta_w):
        ctr["i"] += 1
        bx, by = batch_fn(jax.random.fold_in(KEY, 500 + ctr["i"]), 0)
        return jax.vmap(grad)(theta_w, bx, by)

    solver = prox_adam_solver(grad_fn, adam(0.01), n_steps=5, rho=rho)
    theta0 = jnp.broadcast_to(flat0[None], (n_workers, d)) \
        + 0.01 * jax.random.normal(KEY, (n_workers, d))

    def eval_fn(theta):
        return {"loss": loss(theta, xte, yte),
                "accuracy": acc(theta, xte, yte)}

    return theta0, solver, grad_fn, eval_fn, d, n_workers


def ablation_decentralized(rounds: int = 300):
    """Paper §6 "Decentralized Architecture": chain GADMM with analog
    neighbour links vs the PS-based algorithms — channel uses per round are
    2 (spatial reuse), and no worker ever talks to a central server."""
    import jax.numpy as jnp

    from repro.core.decentralized import (AnalogGadmm,
                                          gadmm_quadratic_solver)
    from repro.data.synthetic import linreg_dataset

    key = jax.random.PRNGKey(11)
    W, d = 8, 6
    X, y, _ = linreg_dataset(key, 2000, d)
    m = 2000 // W
    Xw = X[: m * W].reshape(W, m, d) / jnp.sqrt(m)
    yw = y[: m * W].reshape(W, m) / jnp.sqrt(m)
    theta_star = jnp.linalg.solve(X.T @ X, X.T @ y)
    f = lambda th: float(jnp.mean((y - X @ th) ** 2))

    ccfg = ChannelConfig(n_workers=W, n_subcarriers=d, noisy=True,
                         snr_db=40.0)
    alg = AnalogGadmm(ccfg=ccfg, plan=SubcarrierPlan.build(d, d), rho=1.0)
    solver = gadmm_quadratic_solver(Xw, yw, alg.rho)
    st = alg.init(key, jax.random.normal(key, (W, d)))
    step = jax.jit(lambda st, k: alg.round(k, st, solver, None))
    for i in range(rounds):
        st, met = step(st, jax.random.fold_in(key, i))
    return {
        "final_gap": abs(f(alg.global_model(st)) - f(theta_star)),
        "consensus_gap": float(met["consensus_gap"]),
        "channel_uses_per_round": float(met["channel_uses"]),
    }


def ablation_noniid(rounds: int = 20):
    out = {}
    for split in ("iid", "dirichlet0.3"):
        theta0, solver, grad_fn, eval_fn, d, W = _task(split)
        row = {}
        for name, extra in [("afadmm", None),
                            ("analog_gd", dict(learning_rate=5e-2,
                                               epsilon=1e-6))]:
            acfg = AdmmConfig(rho=0.5, flip_on_change=False,
                              power_control=True)
            ccfg = ChannelConfig(n_workers=W, n_subcarriers=MLP_SUBCARRIERS,
                                 snr_db=40.0)
            alg = make(name, acfg, ccfg, SubcarrierPlan.build(d, MLP_SUBCARRIERS),
                       **(extra or {}))
            hist = train(alg, theta0, solver, grad_fn, rounds,
                         jax.random.fold_in(KEY, 9), eval_fn=eval_fn,
                         eval_every=rounds - 1)
            row[name] = hist.accuracy[-1]
        out[split] = row
    return out
