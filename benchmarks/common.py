"""Shared benchmark infrastructure: the paper's two tasks + algorithm
runners, scaled to run on this CPU container while keeping the paper's
structure (d=6 linreg over 10 subcarriers; 784-128-64-10 MLP over 4096).

Benchmark scale knobs live here so every figure uses consistent settings;
``FAST`` (default) shrinks workers/rounds ~5-10x vs the paper but keeps every
ratio the paper's claims depend on (bandwidth per worker, model/subcarrier
ratio, coherence).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import AdmmConfig, ChannelConfig, SubcarrierPlan, make
from repro.data.synthetic import image_dataset, linreg_dataset
from repro.data.federated import make_batch_fn, split_iid
from repro.models.mlp import init_mlp_flat, make_loss_fns
from repro.optim import adam
from repro.optim.local_solvers import exact_quadratic_solver, prox_adam_solver
from repro.train import History, train

FAST = True

#: OTA transport backend for the A-FADMM runs: "jnp" | "pallas" | unset
#: (unset defers to REPRO_USE_PALLAS, i.e. the same switch the model
#: kernels use).  The figure benchmarks exercise whichever path is selected.
OTA_BACKEND = os.environ.get("REPRO_OTA_BACKEND") or None
#: round driver for every ``train`` call: "scan" (compiled coherence
#: blocks, the default) or "loop" (reference, one dispatch per round).
TRAIN_DRIVER = os.environ.get("REPRO_TRAIN_DRIVER", "scan")

LINREG_WORKERS = 10 if FAST else 100
LINREG_ROUNDS = 300
MLP_WORKERS = 10 if FAST else 100
MLP_SIZES = (64, 32, 16, 10) if FAST else (784, 128, 64, 10)
MLP_IMG_DIM = MLP_SIZES[0]
MLP_SUBCARRIERS = 512 if FAST else 4096
MLP_ROUNDS = 25 if FAST else 200


def _with_ota_backend(name: str, extra: Optional[dict]) -> dict:
    """Algorithm kwargs with the OTA_BACKEND knob applied (afadmm only —
    the other algorithms don't take a transport backend)."""
    kw = dict(extra or {})
    if name == "afadmm" and OTA_BACKEND and "backend" not in kw:
        kw["backend"] = OTA_BACKEND
    return kw


@dataclasses.dataclass
class LinregTask:
    X: jax.Array          # (W, m, d)
    y: jax.Array
    theta0: jax.Array
    f_star: float
    eval_fn: Callable
    grad_fn: Callable
    d: int = 6


def make_linreg_task(key, n_workers: int = LINREG_WORKERS,
                     n_samples: int = 2000) -> LinregTask:
    X, y, _ = linreg_dataset(key, n_samples, 6)
    m = n_samples // n_workers
    Xw = X[: m * n_workers].reshape(n_workers, m, 6) / jnp.sqrt(m)
    yw = y[: m * n_workers].reshape(n_workers, m) / jnp.sqrt(m)
    Xf, yf = X, y

    def f_total(th):
        r = yf - Xf @ th
        return jnp.mean(r * r)

    theta_star = jnp.linalg.solve(Xf.T @ Xf, Xf.T @ yf)
    f_star = float(f_total(theta_star))

    def grad_fn(theta):
        r = jnp.einsum("wmd,wd->wm", Xw, theta) - yw
        return 2.0 * jnp.einsum("wmd,wm->wd", Xw, r)

    def eval_fn(Theta):
        return {"loss": jnp.abs(f_total(Theta) - f_star)}

    theta0 = jax.random.normal(jax.random.fold_in(key, 9),
                               (n_workers, 6))
    return LinregTask(X=Xw, y=yw, theta0=theta0, f_star=f_star,
                      eval_fn=eval_fn, grad_fn=grad_fn)


def linreg_algorithm(name: str, task: LinregTask, *, snr_db=40.0,
                     noisy=True, rho=0.5, n_sub=10, extra=None):
    W = task.theta0.shape[0]
    acfg = AdmmConfig(rho=rho, flip_on_change=True, power_control=True)
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=n_sub, snr_db=snr_db,
                         noisy=noisy)
    plan = SubcarrierPlan.build(task.d, n_sub)
    alg = make(name, acfg, ccfg, plan, **_with_ota_backend(name, extra))
    solver = exact_quadratic_solver(task.X, task.y, rho)
    return alg, solver


@dataclasses.dataclass
class MlpTask:
    theta0: jax.Array
    solver: Callable
    grad_fn: Callable
    eval_fn: Callable
    d: int


def make_mlp_task(key, n_workers: int = MLP_WORKERS, rho: float = 0.5,
                  local_iters: int = 20 if not FAST else 5,
                  lr: float = 0.01, batch: int = 100) -> MlpTask:
    n_train, n_test = (4000, 800) if FAST else (60000, 10000)
    # cluster_std 3.0 keeps the task unsaturated at FAST scale so the
    # algorithm ranking (paper Fig. 3) stays visible
    xtr, ytr, xte, yte = image_dataset(key, n_train, n_test, dim=MLP_IMG_DIM,
                                       cluster_std=3.0)
    shards = split_iid(jax.random.fold_in(key, 1), n_train, n_workers)
    flat0, unflatten = init_mlp_flat(jax.random.fold_in(key, 2), MLP_SIZES)
    d = int(flat0.shape[0])
    loss, grad, acc = make_loss_fns(unflatten)
    batch_fn = make_batch_fn((xtr, ytr), shards, batch_size=batch)

    rng = {"i": 0}

    def sample():
        rng["i"] += 1
        return batch_fn(jax.random.fold_in(key, 10_000 + rng["i"]), 0)

    def grad_fn(theta_w):
        bx, by = sample()
        return jax.vmap(grad)(theta_w, bx, by)

    solver = prox_adam_solver(
        lambda th: grad_fn(th), adam(lr), n_steps=local_iters, rho=rho)

    def eval_fn(theta):
        return {"loss": loss(theta, xte, yte),
                "accuracy": acc(theta, xte, yte)}

    theta0 = jnp.broadcast_to(flat0[None], (n_workers, d)) + \
        0.01 * jax.random.normal(key, (n_workers, d))
    return MlpTask(theta0=theta0, solver=solver, grad_fn=grad_fn,
                   eval_fn=eval_fn, d=d)


def mlp_algorithm(name: str, task: MlpTask, *, snr_db=40.0, noisy=True,
                  rho=0.5, n_sub=MLP_SUBCARRIERS, extra=None):
    W = task.theta0.shape[0]
    acfg = AdmmConfig(rho=rho, flip_on_change=False, power_control=True)
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=n_sub, snr_db=snr_db,
                         noisy=noisy)
    plan = SubcarrierPlan.build(task.d, n_sub)
    return make(name, acfg, ccfg, plan, **_with_ota_backend(name, extra))


def run_train(alg, theta0, solver, grad_fn, rounds, key, **kw) -> History:
    """``repro.train.train`` with the benchmark-wide driver knob applied
    (REPRO_TRAIN_DRIVER=loop reproduces the pre-scan dispatch behaviour)."""
    kw.setdefault("driver", TRAIN_DRIVER)
    return train(alg, theta0, solver, grad_fn, rounds, key, **kw)


def timed(fn: Callable) -> Dict:
    t0 = time.time()
    derived = fn()
    dt = time.time() - t0
    return {"seconds": dt, "derived": derived}
