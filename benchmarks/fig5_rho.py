"""Paper Fig. 5 — sensitivity to the disagreement penalty ρ."""
from __future__ import annotations

import jax

from benchmarks.common import (LINREG_ROUNDS, linreg_algorithm,
                               make_linreg_task)
from benchmarks.common import run_train as train  # scan/loop via env knob

KEY = jax.random.PRNGKey(2)


def fig5_rho_sensitivity(rhos=(0.1, 0.5, 2.0), rounds: int = 150):
    """Linreg loss after a fixed round budget for several ρ — the paper
    observes larger ρ converges faster with diminishing returns."""
    task = make_linreg_task(KEY)
    out = {}
    for rho in rhos:
        alg, solver = linreg_algorithm("afadmm", task, rho=rho, noisy=False)
        hist = train(alg, task.theta0, solver, task.grad_fn,
                     rounds, jax.random.fold_in(KEY, 1),
                     eval_fn=task.eval_fn, eval_every=rounds - 1)
        out[f"rho_{rho:g}"] = {"loss_at_budget": hist.loss[-1]}
    return out
