"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the whole
benchmark in microseconds; derived = the figure's headline numbers as JSON).

    PYTHONPATH=src python -m benchmarks.run [--only fig2a_comm_efficiency]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _benchmarks():
    from benchmarks import (ablation_noniid, fig2_linreg,
                            fig3_classification, fig5_rho,
                            kernels_microbench, roofline, serve_microbench)
    return {
        "ablation_noniid": ablation_noniid.ablation_noniid,
        "ablation_decentralized": ablation_noniid.ablation_decentralized,
        "serve_microbench": serve_microbench.serve_microbench,
        "fig2a_comm_efficiency": fig2_linreg.fig2a_comm_efficiency,
        "fig2b_energy": fig2_linreg.fig2b_energy,
        "fig2c_scalability": fig2_linreg.fig2c_scalability,
        "fig3a_comm_efficiency": fig3_classification.fig3a_comm_efficiency,
        "fig3b_energy": fig3_classification.fig3b_energy,
        "fig3c_scalability": fig3_classification.fig3c_scalability,
        "fig5_rho_sensitivity": fig5_rho.fig5_rho_sensitivity,
        "kernels_microbench": kernels_microbench.microbench,
        "transport_microbench": kernels_microbench.transport_microbench,
        "roofline_summary": roofline.roofline_summary,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    benches = _benchmarks()
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}

    print("name,us_per_call,derived")
    ok = True
    for name, fn in benches.items():
        t0 = time.time()
        try:
            derived = fn()
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},{json.dumps(derived, default=str)}",
                  flush=True)
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"{name},-1,{json.dumps({'error': repr(e)})}", flush=True)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
