"""Generates the §Dry-run and §Roofline sections of EXPERIMENTS.md from
results/dryrun/*.json (run after the sweeps; EXPERIMENTS.md keeps §Perf and
§Paper-validation maintained by hand).

    PYTHONPATH=src python -m benchmarks.report > results/roofline_sections.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.models.registry import get_config

RESULTS = "results/dryrun"


def corrected_model_flops(r: dict) -> float:
    cfg = get_config(r["arch"])
    n_eff = cfg.active_param_count() if cfg.family == "moe" \
        else cfg.param_count()
    m = r["meta"]
    if m["kind"] == "train":
        return 6.0 * n_eff * m["global_batch"] * m["seq"]
    if m["kind"] == "prefill":
        return 2.0 * n_eff * m["global_batch"] * m["seq"]
    return 2.0 * n_eff * m["global_batch"]


def load(mesh: str):
    rows = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        if "_opt-" in p:
            continue
        r = json.load(open(p))
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        mf = corrected_model_flops(r)
        hg = rf["hlo_flops_global"]
        rows.append(dict(
            arch=r["arch"], shape=r["shape"],
            compute=rf["compute_s"], memory=rf["memory_s"],
            coll=rf["collective_s"], dom=rf["dominant"],
            model_flops=mf, hlo_global=hg,
            useful=(mf / hg if hg else float("nan")),
            compile_s=r["timings"]["compile_s"],
            temp_gb=r["memory"].get("temp_size_in_bytes", 0) / 1e9,
            arg_gb=r["memory"].get("argument_size_in_bytes", 0) / 1e9,
            coll_kinds=r["collectives"]["by_kind_bytes"],
            fl_mode=r["meta"].get("fl_mode", "-"),
        ))
    return rows


def dryrun_section() -> str:
    out = ["## §Dry-run", ""]
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        out.append(f"### mesh {mesh} ({256 if mesh == '16x16' else 512} "
                   f"chips) — {len(rows)}/40 combinations lower + compile OK")
        out.append("")
        out.append("| arch | shape | mode | compile s | args GB/dev | "
                   "temp GB/dev | top collective |")
        out.append("|---|---|---|---|---|---|---|")
        for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
            top = max(r["coll_kinds"].items(), key=lambda kv: kv[1],
                      default=("-", 0))
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['fl_mode']} | "
                f"{r['compile_s']:.1f} | {r['arg_gb']:.2f} | "
                f"{r['temp_gb']:.1f} | {top[0]} {top[1]:.2e} B |")
        out.append("")
    return "\n".join(out)


def roofline_section() -> str:
    rows = load("16x16")
    out = ["## §Roofline (single-pod 16x16, 256 chips; TPU v5e model: "
           "197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)", "",
           "Terms are seconds/step per device, derived from the compiled "
           "SPMD HLO with while-loop trip-count correction "
           "(launch/hlo_analysis.py). model_FLOPs = 6·N·D (train), 2·N·D "
           "(prefill), 2·N·B (decode); N = active params for MoE.", "",
           "| arch | shape | compute s | memory s | collective s | dominant "
           "| useful FLOP frac |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute']:.3e} | "
            f"{r['memory']:.3e} | {r['coll']:.3e} | {r['dom']} | "
            f"{r['useful']:.2f} |")
    out.append("")
    doms = {}
    for r in rows:
        doms[r["dom"]] = doms.get(r["dom"], 0) + 1
    out.append(f"Dominant-term census: {doms}.")
    return "\n".join(out)


if __name__ == "__main__":
    print(dryrun_section())
    print()
    print(roofline_section())
