"""Roofline report: aggregates results/dryrun/*.json into the per-(arch x
shape x mesh) table required by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_all(results_dir: str = RESULTS_DIR) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(results_dir: str = RESULTS_DIR) -> List[Dict]:
    rows = []
    for r in load_all(results_dir):
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "model_flops": rf["model_flops"],
            "hlo_flops_global": rf["hlo_flops_global"],
            "useful_flop_fraction": rf["useful_flop_fraction"],
            "compile_s": r["timings"]["compile_s"],
        })
    return rows


def markdown_table(results_dir: str = RESULTS_DIR,
                   mesh: str = "16x16") -> str:
    rows = [r for r in table(results_dir) if r["mesh"] == mesh]
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful FLOP frac |",
             "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        uf = r["useful_flop_fraction"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {uf:.2f} |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | - |")
    return "\n".join(lines)


def roofline_summary() -> Dict:
    rows = table()
    if not rows:
        return {"n_results": 0}
    dominant_counts: Dict[str, int] = {}
    for r in rows:
        dominant_counts[r["dominant"]] = \
            dominant_counts.get(r["dominant"], 0) + 1
    worst = min((r for r in rows if r["shape"] == "train_4k"
                 and r["useful_flop_fraction"]),
                key=lambda r: r["useful_flop_fraction"], default=None)
    return {
        "n_results": len(rows),
        "dominant_counts": dominant_counts,
        "worst_useful_flop_fraction":
            {k: worst[k] for k in ("arch", "shape", "useful_flop_fraction")}
            if worst else None,
    }
